"""LM production pipeline through the StreamFlow layer: the paper's hybrid
pattern applied to an ML lifecycle.

    /tokenize   (cloud)  corpus -> packed token shards
    /pretrain   (HPC)    real JAX training, checkpointing inside the step
    /eval       (cloud)  held-out perplexity from the trained params
    /export     (cloud)  int8-quantized parameter package

The trained parameters cross the HPC->cloud boundary once (two-step copy);
eval and export then stay cloud-local (R4 keeps the params in place).

    PYTHONPATH=src python examples/lm_pipeline.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import (ModelSpec, Step, StreamFlowExecutor,  # noqa: E402
                        Workflow)
from repro.core.streamflow_file import Binding  # noqa: E402
from repro.configs.paper_pipeline import tiny_lm  # noqa: E402

CFG = tiny_lm(vocab=512, d_model=64, n_layers=2)


def tokenize(inputs, ctx):
    from repro.data.synthetic import SyntheticCorpus, pack_documents
    corpus = SyntheticCorpus(CFG.vocab_size, seed=int(inputs["seed"]))
    it = corpus.documents(0)
    return {"train_shard": pack_documents(it, 128, 64),
            "eval_shard": pack_documents(it, 128, 16)}


def pretrain(inputs, ctx):
    import jax
    import jax.numpy as jnp
    from repro.models import registry as R
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    shard = inputs["shard"]
    params, _ = R.init_params(jax.random.key(0), CFG)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30)
    opt = adamw_init(params)

    @jax.jit
    def step(p, o, tok, lab):
        (l, m), g = jax.value_and_grad(
            lambda q: R.forward_train(q, CFG, {"tokens": tok, "labels": lab}),
            has_aux=True)(p)
        p, o, _ = adamw_update(g, o, p, ocfg)
        return p, o, l

    losses = []
    for s in range(30):
        lo = (s * 8) % (shard.shape[0] - 8)
        blk = shard[lo:lo + 8]
        params, opt, loss = step(params, opt, jnp.asarray(blk[:, :-1]),
                                 jnp.asarray(blk[:, 1:]))
        losses.append(float(loss))
    return {"trained_params": jax.tree.map(np.asarray, params),
            "train_log": {"losses": losses}}


def evaluate(inputs, ctx):
    import jax
    import jax.numpy as jnp
    from repro.models import registry as R
    params = jax.tree.map(jnp.asarray, inputs["params"])
    shard = inputs["shard"]
    loss, m = jax.jit(lambda p, t, l: R.forward_train(
        p, CFG, {"tokens": t, "labels": l}))(
        params, jnp.asarray(shard[:, :-1]), jnp.asarray(shard[:, 1:]))
    return {"eval_report": {"nll": float(m["nll"]),
                            "ppl": float(np.exp(min(float(m["nll"]), 20.0))),
                            "acc": float(m["acc"])}}


def export(inputs, ctx):
    from repro.optim import quantize_int8
    import jax
    package = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            inputs["params"])[0]:
        import jax.numpy as jnp
        q, scale = quantize_int8(jnp.asarray(leaf))
        key = jax.tree_util.keystr(path)
        package[key] = {"int8": np.asarray(q), "scale": float(scale)}
    nbytes = sum(v["int8"].nbytes for v in package.values())
    return {"package": {"n_tensors": len(package), "int8_bytes": nbytes}}


def build_workflow():
    wf = Workflow("lm-pipeline")
    wf.add_step(Step("/tokenize", tokenize, {"seed": "seed"},
                     ("train_shard", "eval_shard")))
    wf.add_step(Step("/pretrain", pretrain, {"shard": "train_shard"},
                     ("trained_params", "train_log")))
    wf.add_step(Step("/eval", evaluate, {"params": "trained_params",
                                         "shard": "eval_shard"},
                     ("eval_report",)))
    wf.add_step(Step("/export", export, {"params": "trained_params"},
                     ("package",)))
    wf.validate()
    return wf


def main():
    models = {
        "hpc": ModelSpec("hpc", "mesh", {
            "topology": {"data": 16, "model": 16},
            "services": {"trainer": {"replicas": 1, "cores": 4,
                                     "memory_gb": 16}}}),
        "cloud": ModelSpec("cloud", "local", {
            "services": {"worker": {"replicas": 2}}}),
    }
    bindings = [
        Binding("/", "cloud", "worker"),
        Binding("/pretrain", "hpc", "trainer"),
    ]
    ex = StreamFlowExecutor(models)
    res = ex.run(build_workflow(), bindings, inputs={"seed": 0})

    log = res.outputs["train_log"]["losses"]
    rep = res.outputs["eval_report"]
    pkg = res.outputs["package"]
    print(f"\n[pipeline] train loss {log[0]:.3f} -> {log[-1]:.3f}")
    print(f"[pipeline] eval nll={rep['nll']:.3f} ppl={rep['ppl']:.1f} "
          f"acc={rep['acc']:.3f}")
    print(f"[pipeline] exported {pkg['n_tensors']} tensors, "
          f"{pkg['int8_bytes']:,} int8 bytes")
    print("[pipeline] transfers:",
          {k: (int(v['n']), int(v['bytes']))
           for k, v in ex.data.transfer_summary().items()})
    assert log[-1] < log[0], "training did not improve"


if __name__ == "__main__":
    main()
