"""Crash-recovery walkthrough: kill the driver mid-workflow, then resume.

    PYTHONPATH=src python examples/resume_after_crash.py

What happens: the recovery-demo diamond workflow (fan-out of hash-chain
transforms + a reduce) runs against two *external* (user-managed) sites
with a write-ahead execution journal enabled.  A tick hook kills the
driver once two steps have completed — the sites, and the output tokens
in their stores, survive.  A brand-new executor then calls ``resume()``
with nothing but the journal: the workflow and bindings are rebuilt from
the journaled builder reference, each completed step's outputs are
verified through the Connector, and only the lost frontier re-executes.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (FaultConfig, StreamFlowExecutor,   # noqa: E402
                        load_streamflow_file, start_external_site,
                        stop_external_site)
from repro.configs import recovery_demo                    # noqa: E402

JOURNAL = ".streamflow/resume_demo.jsonl"


class DriverKilled(BaseException):
    pass


def main():
    if os.path.exists(JOURNAL):
        os.unlink(JOURNAL)                 # a fresh drill each invocation
    for name, site_cfg in recovery_demo.site_configs().items():
        start_external_site(name, "local", site_cfg)

    doc = recovery_demo.streamflow_doc(journal_path=JOURNAL)
    cfg = load_streamflow_file(doc)
    ex = StreamFlowExecutor.from_config(cfg,
                                        fault=FaultConfig(speculative=False))

    def kill_between_ticks(tick, completed):
        if len(completed) >= 2:
            raise DriverKilled(f"simulated crash; done={sorted(completed)}")
    ex.tick_hook = kill_between_ticks

    entry = cfg.workflows["recovery-demo"]
    try:
        ex.run(entry.workflow, entry.bindings, inputs={"seed": 0})
    except DriverKilled as e:
        print(f"driver died: {e}")

    print(f"\nresuming from {JOURNAL} with a brand-new executor ...")
    ex2 = StreamFlowExecutor.from_config(load_streamflow_file(doc),
                                         fault=FaultConfig(speculative=False))
    res = ex2.resume()
    rerun = sorted(e.step for e in res.events if e.status == "completed")
    print(f"re-executed only the lost frontier: {rerun}")
    print(f"combined digest head: {res.outputs['combined'][:4]}")
    stop_external_site()


if __name__ == "__main__":
    main()
