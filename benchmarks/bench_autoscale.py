"""Elastic replicas vs a static pool (PR 9 autoscaler).

Three runs of the same embarrassingly-parallel workload (``N_STEPS``
independent ``WORK_S``-second steps bound to one 1-slot site):

  static     no ``autoscale:`` block — the control.  One resource, so the
             whole batch serializes: makespan ~= ``N_STEPS * WORK_S``
  elastic    ``autoscale.models.site.max = MAX_REPLICAS`` — queue pressure
             grows the pool to ``MAX_REPLICAS`` sites and the batch runs
             ~``MAX_REPLICAS``-wide; scale-up placement reuses the PR-4
             topology clone, so replicas inherit the base site's links
  preempted  elastic + ``preemptible: true``, with a revocation driver
             that kills a replica *while it has work in flight* (spot
             semantics).  The run must still complete — dead attempts
             retry on survivors, never the revoked site — and the wasted
             work (attempts lost to revocations) must stay a bounded
             fraction of the useful work

``compare.py`` gates two claims: growing the pool beats the static
control (``autoscale_makespan_ratio`` < 1, elastic/static wall in one
process) and revocation waste is bounded
(``autoscale_wasted_work_ratio``: wasted attempts per useful invocation).
"""
from __future__ import annotations

import time

from repro.core import FaultConfig, ModelSpec, StreamFlowExecutor
from repro.core.streamflow_file import Binding
from repro.core.workflow import Requirements, Step, Workflow

N_STEPS = 16
WORK_S = 0.05
MAX_REPLICAS = 4               # 1 base + 3 clones
N_PREEMPTS = 2


def _models():
    return {"site": ModelSpec("site", "local",
                              {"services": {"svc": {"replicas": 1}}})}


def _bindings():
    return [Binding("/", "site", "svc")]


def _workflow() -> Workflow:
    wf = Workflow("autoscale-bench")
    for i in range(N_STEPS):
        def fn(inputs, ctx, i=i):
            time.sleep(WORK_S)
            return {f"out{i}": inputs["seed"] + i}
        wf.add_step(Step(f"/work{i}", fn, {"seed": "seed"}, (f"out{i}",),
                         requirements=Requirements(cores=1)))
    return wf


def _autoscale(preemptible: bool) -> dict:
    return {"models": {"site": {"min": 1, "max": MAX_REPLICAS,
                                "target_queue_depth": 1,
                                "preemptible": preemptible}}}


def _run(mode: str) -> dict:
    ex = StreamFlowExecutor(
        _models(), fault=FaultConfig(speculative=False),
        max_workers=MAX_REPLICAS * 2,
        autoscale=None if mode == "static" else _autoscale(
            preemptible=(mode == "preempted")))

    state = {"preempts": 0}
    if mode == "preempted":
        def hook(tick, completed):
            sc = ex.autoscaler
            if state["preempts"] >= N_PREEMPTS or len(completed) < 2:
                return          # let the pool grow and work start first
            for rep in sc.replicas("site"):
                if ex.scheduler.running_on(rep):   # spot revocation lands
                    state["preempts"] += 1         # mid-step, by design
                    sc.preempt(rep)
                    break
        ex.tick_hook = hook

    t0 = time.time()
    res = ex.run(_workflow(), _bindings(), {"seed": 1})
    wall = time.time() - t0
    assert len(res.outputs) == N_STEPS, "benchmark run lost outputs"
    scaler = ex.autoscaler
    return {
        "mode": mode,
        "makespan_s": round(wall, 4),
        "useful_invocations": N_STEPS,
        "wasted_invocations": res.wasted_invocations,
        "wasted_seconds": round(res.wasted_seconds, 4),
        "scale_ups": scaler.scale_up_events if scaler else 0,
        "preempts": state["preempts"],
    }


def run() -> list:
    rows = [_run("static"), _run("elastic"), _run("preempted")]
    print(f"{'mode':<12} {'makespan_s':>10} {'scale_ups':>9} "
          f"{'preempts':>8} {'wasted':>6} {'wasted_s':>8}")
    for r in rows:
        print(f"{r['mode']:<12} {r['makespan_s']:>10} {r['scale_ups']:>9} "
              f"{r['preempts']:>8} {r['wasted_invocations']:>6} "
              f"{r['wasted_seconds']:>8}")
    by = {r["mode"]: r for r in rows}
    ratio = by["elastic"]["makespan_s"] / max(by["static"]["makespan_s"],
                                             1e-9)
    print(f"\nelastic/static makespan: {ratio:.3f} "
          f"(pool grew {by['elastic']['scale_ups']}x); preempted run "
          f"wasted {by['preempted']['wasted_invocations']} attempt(s) "
          f"across {by['preempted']['preempts']} revocation(s)")
    return rows


if __name__ == "__main__":
    run()
