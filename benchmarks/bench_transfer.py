"""Paper §4.6 / R3-R4: transfer-strategy comparison across payload sizes.

Measures the three channels the DataManager picks between — two-step relay
(inter-model baseline), intra-model single hop, shared-space/elided — and
shows the R4 elision win on repeat transfers.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import DataManager, DeploymentManager, ModelSpec


def _world(shared=False):
    dm = DeploymentManager({
        "hpc": ModelSpec("hpc", "local", {
            "services": {"x": {"replicas": 2}}, "shared_store": shared}),
        "cloud": ModelSpec("cloud", "local", {
            "services": {"y": {"replicas": 1}}}),
    })
    dm.deploy("hpc")
    dm.deploy("cloud")
    return DataManager(dm)


def run(verbose=True):
    rows = []
    for mb in (1, 8, 32):
        payload = np.random.default_rng(0).standard_normal(
            mb * 131072).astype(np.float32)          # mb MiB
        for mode, shared in (("separate", False), ("shared-fs", True)):
            d = _world(shared=shared)
            ref = d.put("tok", payload)
            t0 = time.time()
            r1 = d.transfer_sync(ref, "hpc", "hpc/x/0")      # seed site
            r2 = d.transfer_sync(ref, "hpc", "hpc/x/1")      # intra-model
            r3 = d.transfer_sync(ref, "cloud", "cloud/y/0")  # two-step
            r4 = d.transfer_sync(ref, "cloud", "cloud/y/0")  # R4 elide
            rows.append({
                "MiB": mb, "mode": mode,
                "intra_kind": r2.kind, "intra_s": r2.seconds,
                "two_step_s": r3.seconds, "two_step_bytes": r3.bytes,
                "elided_kind": r4.kind, "elided_s": r4.seconds,
                "total_s": time.time() - t0,
            })
    if verbose:
        hdr = list(rows[0])
        print(" | ".join(f"{h:>14s}" for h in hdr))
        for r in rows:
            print(" | ".join(f"{str(round(r[h], 5) if isinstance(r[h], float) else r[h]):>14s}"
                             for h in hdr))
        two = [r for r in rows if r["mode"] == "separate"]
        print(f"\n[claim] R4 elision: repeat transfer costs "
              f"{two[-1]['elided_s']:.5f}s vs two-step "
              f"{two[-1]['two_step_s']:.5f}s "
              f"({two[-1]['two_step_s'] / max(two[-1]['elided_s'], 1e-9):.0f}x)")
        sh = [r for r in rows if r["mode"] == "shared-fs"]
        print(f"[claim] shared data space turns intra-model copies into "
              f"'{sh[-1]['intra_kind']}' (Occam /scratch analogue)")
    return rows


def main(argv=None):
    run()


if __name__ == "__main__":
    main()
