"""Paper §4.5: model-lifecycle strategies under injected deploy latency.

lazy (paper default: deploy at first fireable task) vs eager (deploy-all
upfront) vs grace-period undeploy (beyond-paper).  Metric: wall clock +
site-seconds held (the 'cloud cost' proxy the paper argues lazy saves).
"""
from __future__ import annotations

import time

from repro.configs.paper_pipeline import streamflow_doc_hybrid
from repro.core import StreamFlowExecutor, load_streamflow_file
from benchmarks.common import warmup, WF_ARGS

DEPLOY_DELAY = 0.3


def _doc():
    doc = streamflow_doc_hybrid(**WF_ARGS)
    for m in doc["models"].values():
        m["config"]["deploy_delay_s"] = DEPLOY_DELAY
    return doc


def _site_seconds(dep_timeline, t_end):
    """Sum over models of (undeploy - deploy) holding time."""
    open_at = {}
    total = 0.0
    for model, event, t0, t1 in dep_timeline:
        if event == "deploy":
            open_at[model] = t1
        else:
            total += t1 - open_at.pop(model, t1)
    for model, t in open_at.items():
        total += t_end - t
    return total


def run(verbose=True):
    warmup()
    rows = []
    for strategy in ("lazy", "eager", "grace"):
        cfg = load_streamflow_file(_doc())
        if strategy == "grace":
            cfg.grace_period_s = 0.15
        ex = StreamFlowExecutor.from_config(cfg)
        entry = cfg.workflows["single-cell"]
        t0 = time.time()
        if strategy == "eager":
            for m in cfg.models:
                ex._ensure_deployed(m)
        res = ex.run(entry.workflow, entry.bindings, inputs={"seed": 0})
        wall = time.time() - t0
        rows.append({
            "strategy": strategy, "wall_s": round(wall, 3),
            "site_s": round(_site_seconds(res.deployment_timeline,
                                          t0 + wall), 3),
            "deploys": len([e for e in res.deployment_timeline
                            if e[1] == "deploy"]),
        })
    if verbose:
        hdr = list(rows[0])
        print(" | ".join(f"{h:>10s}" for h in hdr))
        for r in rows:
            print(" | ".join(f"{str(r[h]):>10s}" for h in hdr))
        print(f"\n[claim] lazy allocation defers site holding "
              f"(site-seconds: lazy={rows[0]['site_s']} vs "
              f"eager={rows[1]['site_s']}); grace-period re-deploys "
              f"when idle sites are reclaimed early "
              f"(deploys={rows[2]['deploys']})")
    return rows


def main(argv=None):
    run()


if __name__ == "__main__":
    main()
