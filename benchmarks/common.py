"""Shared benchmark plumbing."""
from __future__ import annotations

import time
from typing import Dict

from repro.core import StreamFlowExecutor, load_streamflow_file

WF_ARGS = dict(n_chains=4, train_steps=3, rows_per_chain=12, seq_len=64,
               batch=4, vocab=256, d_model=48)

_WARM = False


def warmup():
    """Populate the jit caches once so benchmark walls measure execution,
    not first-call compilation."""
    global _WARM
    if _WARM:
        return
    from repro.configs.paper_pipeline import (streamflow_doc_full_hpc,
                                              streamflow_doc_hybrid,
                                              streamflow_doc_single_service)
    # keep tensor shapes identical to WF_ARGS so every jit cache is hot,
    # and warm BOTH execution contexts (mesh site and local site) — the
    # jit cache keys on the ambient mesh
    # NOTE: train_steps must match too — the jitted step is cached per
    # optimizer schedule constants
    args = {**WF_ARGS, "n_chains": 1}
    run_doc(streamflow_doc_full_hpc(**args))
    run_doc(streamflow_doc_hybrid(**args))
    # the single-service pool runs the *train* step on the local context,
    # which the two docs above never warm — without this, whichever policy
    # ran first was charged ~30s of jit compile
    run_doc(streamflow_doc_single_service(**args))
    _WARM = True


def run_doc(doc, *, policy=None, fault=None, **executor_kw):
    cfg = load_streamflow_file(doc)
    if policy:
        cfg.policy = policy
    ex = StreamFlowExecutor.from_config(cfg, **executor_kw)
    if fault is not None:
        ex.fault = fault
    name, entry = next(iter(cfg.workflows.items()))
    t0 = time.time()
    res = ex.run(entry.workflow, entry.bindings, inputs={"seed": 0})
    return ex, res, time.time() - t0


def ascii_timeline(res, width: int = 60) -> str:
    rows = res.timeline_rows()
    if not rows:
        return "(empty)"
    t_end = max(r[3] for r in rows) or 1.0
    out = []
    for step, resource, t0, t1, status, attempt, spec in rows:
        a = int(t0 / t_end * width)
        b = max(int(t1 / t_end * width), a + 1)
        bar = " " * a + "#" * (b - a)
        tag = "*" if spec else ("!" if status.startswith("failed") else "")
        out.append(f"{step:<22s}|{bar:<{width}}| {t1 - t0:6.2f}s {tag}")
    return "\n".join(out)


def transfer_line(ex) -> Dict[str, str]:
    s = ex.data.transfer_summary()
    return {k: f"n={int(v['n'])} bytes={int(v['bytes'])} "
               f"t={v['seconds']:.3f}s" for k, v in sorted(s.items())}
