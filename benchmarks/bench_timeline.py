"""Paper Fig. 8 / Fig. 9: full-HPC vs hybrid execution timelines.

Validated claim (paper §5.2): the hybrid HPC+cloud run's wall clock is
comparable to the full-HPC run because inter-site transfer time is
negligible vs. task time, and the locality-aware scheduler removes the
avoidable copies (R4).
"""
from __future__ import annotations

import argparse

from repro.configs.paper_pipeline import (streamflow_doc_full_hpc,
                                          streamflow_doc_hybrid)
from benchmarks.common import warmup, WF_ARGS, ascii_timeline, run_doc, transfer_line


def run(config: str = "both", wf_args=None, verbose=True):
    warmup()
    wf_args = wf_args or WF_ARGS
    out = {}
    docs = {}
    if config in ("fullsite", "both"):
        docs["full-hpc (Fig.8)"] = streamflow_doc_full_hpc(**wf_args)
    if config in ("hybrid", "both"):
        docs["hybrid (Fig.9)"] = streamflow_doc_hybrid(**wf_args)
    for name, doc in docs.items():
        ex, res, wall = run_doc(doc)
        xfer = ex.data.transfer_summary()
        remote_s = sum(v["seconds"] for k, v in xfer.items()
                       if k in ("two-step", "intra-model"))
        task_s = sum(e.end - e.start for e in res.events
                     if e.status == "completed")
        out[name] = {"wall_s": wall, "task_s": task_s,
                     "transfer_s": remote_s,
                     "transfer_frac": remote_s / max(task_s, 1e-9)}
        if verbose:
            print(f"\n== {name}: wall={wall:.2f}s  "
                  f"transfer={remote_s:.3f}s "
                  f"({100 * out[name]['transfer_frac']:.2f}% of task time)")
            print(ascii_timeline(res))
            for k, v in transfer_line(ex).items():
                print(f"   {k:<12s} {v}")
    if len(out) == 2 and verbose:
        a, b = out.values()
        ratio = b["wall_s"] / a["wall_s"]
        print(f"\n[claim] hybrid/full-HPC wall ratio = {ratio:.2f} "
              f"(paper: ~1.0); transfer overhead "
              f"{100 * b['transfer_frac']:.2f}% (paper: negligible)")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--config", choices=["fullsite", "hybrid", "both"],
                    default="both")
    args = ap.parse_args(argv)
    run(args.config)


if __name__ == "__main__":
    main()
