"""Crash-recovery on the hybrid Fig. 9 topology: recovered-makespan vs
from-scratch.

The drill: run the paper's hybrid single-cell workflow with the execution
journal enabled and *kill the driver* (tick-hook crash) once half the steps
have completed — the heavy HPC-side ``count`` training steps.  The sites
are marked ``external`` (user-managed, as on the real Occam + GARR cloud),
so their stores survive the driver: ``Executor.resume`` re-attaches,
verifies each journaled token through the Connector, skips the completed
steps and re-fires only the lost frontier.  The claim: resuming costs only
the unfinished tail, so recovered makespan is well below a from-scratch
re-run of the whole workflow.
"""
from __future__ import annotations

import os
import tempfile

from benchmarks.common import WF_ARGS, run_doc, warmup
from repro.core import (FaultConfig, StreamFlowExecutor,
                        load_streamflow_file, start_external_site,
                        stop_external_site)
from repro.configs.paper_pipeline import streamflow_doc_hybrid

LINK = {"link_latency_s": 0.05, "link_bandwidth_mbps": 200.0}
CRASH_AFTER = 1 + WF_ARGS["n_chains"] // 2   # mkfastq + half the counts


class _DriverKilled(BaseException):
    pass


def _doc(journal_path: str) -> dict:
    doc = streamflow_doc_hybrid(**WF_ARGS)
    for model in doc["models"].values():
        model["config"].update(LINK)
        model["external"] = True                 # sites outlive the driver
    doc["checkpoint"] = {"journal_path": journal_path}
    return doc


def _fresh_sites(doc):
    stop_external_site()
    for name, m in doc["models"].items():
        start_external_site(name, m["type"], m["config"])


def _makespan(res) -> float:
    rows = res.timeline_rows()
    return max(r[3] for r in rows) - min(r[2] for r in rows)


def run(verbose=True):
    warmup()
    fault = FaultConfig(speculative=False)
    workdir = tempfile.mkdtemp(prefix="sf-recovery-")

    # -- from-scratch reference (fresh sites, journal on: same write costs)
    doc = _doc(os.path.join(workdir, "scratch.jsonl"))
    _fresh_sites(doc)
    _, res, scratch_wall = run_doc(doc, fault=fault)
    scratch = {"makespan_s": round(_makespan(res), 3),
               "wall_s": round(scratch_wall, 3),
               "steps_executed": len([e for e in res.events
                                      if e.status == "completed"])}

    # -- crash the driver mid-run, then resume from the journal
    jp = os.path.join(workdir, "crashed.jsonl")
    doc = _doc(jp)
    _fresh_sites(doc)
    cfg = load_streamflow_file(doc)
    ex = StreamFlowExecutor.from_config(cfg, fault=fault)

    def killer(tick, completed):
        if len(completed) >= CRASH_AFTER:
            raise _DriverKilled
    ex.tick_hook = killer
    entry = cfg.workflows["single-cell"]
    try:
        ex.run(entry.workflow, entry.bindings, inputs={"seed": 0})
        raise RuntimeError("crash hook never fired")
    except _DriverKilled:
        pass

    ex2 = StreamFlowExecutor.from_config(load_streamflow_file(doc),
                                         fault=fault)
    res2 = ex2.resume()                          # everything from the WAL
    recovered = {"makespan_s": round(_makespan(res2), 3),
                 "wall_s": round(res2.wall_seconds, 3),
                 "steps_executed": len([e for e in res2.events
                                        if e.status == "completed"])}
    stop_external_site()

    rows = [{"phase": "from-scratch", **scratch},
            {"phase": "resumed", **recovered}]
    if verbose:
        hdr = ["phase", "makespan_s", "wall_s", "steps_executed"]
        print(" | ".join(f"{h:>16s}" for h in hdr))
        for r in rows:
            print(" | ".join(f"{str(r[h]):>16s}" for h in hdr))
        ratio = scratch["makespan_s"] / max(recovered["makespan_s"], 1e-9)
        print(f"\n[claim] driver killed after {CRASH_AFTER} steps; resume "
              f"re-executed {recovered['steps_executed']} of "
              f"{scratch['steps_executed']} steps and finished in "
              f"{recovered['makespan_s']:.3f}s vs {scratch['makespan_s']:.3f}s "
              f"from scratch ({ratio:.2f}x faster): completed work is never "
              f"recomputed, only the lost frontier runs")
    return rows


def main(argv=None):
    run()


if __name__ == "__main__":
    main()
