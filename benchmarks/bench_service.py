"""Multi-tenant service throughput: pooled vs per-run deployments.

A bursty open-loop arrival process submits ``N_RUNS`` short two-site
workflows (prep on ``ingest``, reduce on ``compute``) to a
``WorkflowService``:

  pooled     the PR-6 deployment pool — ONE shared ``DeploymentManager``
             behind per-run lease façades + one shared scheduler; a run's
             "deploy" is a refcounted lease, sites persist across runs
             (idle keep-alive), and every run pays the ~``DEPLOY_DELAY_S``
             site bring-up at most once *per pool*, not per run
  per-run    the control: ``pool.enabled: false`` — every run gets its own
             managers and physically deploys both sites itself, exactly
             what looping ``Executor.run`` did before the service existed

Same workload, same arrival schedule, same ``max_concurrent`` (the
service genuinely holds >= 100 runs in flight at the burst peaks).
Reported per variant: wall, throughput (runs/s), mean/p99 end-to-end run
latency (submit -> terminal), physical deploy count, and peak concurrent
RUNNING runs.  ``compare.py`` gates two claims: pooling buys throughput
(``service_throughput_ratio`` >= 1) and slashes tail latency
(``service_p99_ratio`` < 1) — with 2 models serving ``N_RUNS`` runs, the
deploy count is the whole story (2 vs ``2 * N_RUNS``).
"""
from __future__ import annotations

import time

from repro.core import FaultConfig, ModelSpec, ServiceConfig, WorkflowService
from repro.core.streamflow_file import Binding
from repro.core.workflow import Requirements, Step, Workflow

MAX_CONCURRENT = 100
DEPLOY_DELAY_S = 0.25          # per-site bring-up the pool amortizes
# open-loop arrival process: one saturating burst (drives the service to
# its MAX_CONCURRENT cap and warms the pool), then steady-state bursts —
# the latency measurement window (warmup excluded, standard practice)
WARMUP_BURST = 100
STEADY_BURSTS = 4
STEADY_BURST_SIZE = 20
BURST_GAP_S = 0.15
WARMUP_GAP_S = 0.6             # let the warmup wave mostly drain first
N_RUNS = WARMUP_BURST + STEADY_BURSTS * STEADY_BURST_SIZE
REPLICAS = 64                  # shared-pool slots per service


def _models():
    return {
        "ingest": ModelSpec("ingest", "local", {
            "deploy_delay_s": DEPLOY_DELAY_S,
            "services": {"svc": {"replicas": REPLICAS}}}),
        "compute": ModelSpec("compute", "local", {
            "deploy_delay_s": DEPLOY_DELAY_S,
            "services": {"svc": {"replicas": REPLICAS}}}),
    }


def _bindings():
    return [Binding("/prep", "ingest", "svc"),
            Binding("/reduce", "compute", "svc")]


def _workflow(run_idx: int) -> Workflow:
    """Tiny two-step chain touching BOTH sites, so a per-run service
    pays two deploys per run."""
    import numpy as np
    wf = Workflow(f"svc-bench-{run_idx}")

    def prep(inputs, ctx):
        x = np.arange(64, dtype=np.float64) * (1 + int(inputs["seed"]))
        return {"vec": x}

    def reduce_(inputs, ctx):
        return {"total": float(inputs["vec"].sum())}

    wf.add_step(Step("/prep", prep, {"seed": "seed"}, ("vec",),
                     requirements=Requirements(cores=1)))
    wf.add_step(Step("/reduce", reduce_, {"vec": "vec"}, ("total",),
                     requirements=Requirements(cores=1)))
    return wf


def _drive(pooled: bool) -> dict:
    cfg = ServiceConfig(max_concurrent=MAX_CONCURRENT,
                        pool_enabled=pooled, keepalive_s=60.0)
    svc = WorkflowService(_models(), service=cfg,
                          fault=FaultConfig(speculative=False),
                          max_workers=2, transfer_workers=1,
                          deadlock_timeout_s=10.0)
    bindings = _bindings()
    t0 = time.time()
    rids = []
    for idx in range(WARMUP_BURST):
        rids.append(svc.submit(_workflow(idx), bindings, {"seed": idx}))
    peak = len(svc.list_runs(state="RUNNING"))   # the cap, if saturated
    time.sleep(WARMUP_GAP_S)
    for burst in range(STEADY_BURSTS):
        for i in range(STEADY_BURST_SIZE):
            idx = WARMUP_BURST + burst * STEADY_BURST_SIZE + i
            rids.append(svc.submit(_workflow(idx), bindings,
                                   {"seed": idx}))
        if burst < STEADY_BURSTS - 1:
            time.sleep(BURST_GAP_S)
    svc.drain(timeout=600)
    wall = time.time() - t0

    infos = [svc.status(r) for r in rids]
    bad = [i.id for i in infos if i.state != "COMPLETE"]
    if bad:
        raise RuntimeError(f"{len(bad)} run(s) not COMPLETE: {bad[:5]}")
    # latency window: steady-state submissions only (per-run deploys every
    # time; a warm pool deploys never — that gap is the claim under test)
    lats = sorted(i.finished_at - i.submitted_at
                  for i in infos[WARMUP_BURST:])
    if pooled:
        deploys = svc.pool.deploy_count
    else:
        deploys = sum(
            sum(1 for e in svc._runs[r].result.deployment_timeline
                if e[1] == "deploy") for r in rids)
    svc.close()
    p99 = lats[min(len(lats) - 1, int(len(lats) * 0.99))]
    return {
        "variant": "pooled" if pooled else "per-run",
        "runs": N_RUNS,
        "wall_s": round(wall, 3),
        "throughput_rps": round(N_RUNS / wall, 3),
        "lat_mean_s": round(sum(lats) / len(lats), 4),
        "lat_p99_s": round(p99, 4),
        "deploys": deploys,
        "peak_running": peak,
        "max_concurrent": MAX_CONCURRENT,
    }


def run():
    rows = [_drive(pooled=False), _drive(pooled=True)]
    by = {r["variant"]: r for r in rows}
    by["pooled"]["throughput_ratio"] = round(
        by["pooled"]["throughput_rps"] / by["per-run"]["throughput_rps"], 4)
    by["pooled"]["p99_ratio"] = round(
        by["pooled"]["lat_p99_s"] / by["per-run"]["lat_p99_s"], 4)
    return rows


def main():
    for r in run():
        print(r)


if __name__ == "__main__":
    main()
