"""Cross-run invocation memoization: warm re-run vs cold run.

One 16-wide scatter workflow (/split -> /process x16 -> /train x16 ->
/aggregate, 34 invocations) is submitted twice to the same
``WorkflowService`` with the ``cache:`` block on (scope=service) and the
deployment pool keeping sites warm between runs:

  cold    first submission — every invocation executes, every output is
          recorded in the invocation cache (digest + size + live site
          location), and the transfer log pays the full input/feature
          movement
  warm    identical workflow, identical inputs, fresh run id — every
          invocation's memo key (command identity + resolved input
          digests + scatter tag) hits, the recorded outputs verify live
          on the pooled sites (liveness ping + digest recheck), and the
          run completes by CAS-aliasing cached payloads into its own
          namespace: zero compute, zero payload movement

Each /process fans a ~64 KiB feature across sites, so the cold run moves
megabytes where the warm run moves only the final report collection.
Reported per phase: makespan, invocation/executed/memoized counts, hit
rate, and transfer-log bytes.  ``compare.py`` gates three claims: the
warm makespan is at most half the cold one (``cache_warm_makespan_ratio``,
in practice ~0.1x — the per-invocation compute cost is never paid), the
warm run moves a small fraction of the cold run's bytes
(``cache_bytes_ratio``), and at least 90% of invocations memoize
(``cache_hit_rate`` — in practice all 34 do).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

from repro.core import (CacheConfig, ModelSpec, Requirements, ServiceConfig,
                        Step, Workflow, WorkflowService)
from repro.core.streamflow_file import Binding

N_SAMPLES = 16
STEP_COST_S = 0.06             # per-invocation compute the warm run skips
FEATURE_FLOATS = 8192          # ~64 KiB per /process output
HPC_SLOTS = 8
CLOUD_SLOTS = 8
REPEATS = 3


def _models():
    return {
        "hpc": ModelSpec("hpc", "local", {
            "services": {"svc": {"replicas": HPC_SLOTS}}}),
        "cloud": ModelSpec("cloud", "local", {
            "services": {"svc": {"replicas": CLOUD_SLOTS}}}),
    }


def _bindings():
    # /train on the other site forces a cross-site feature hop per sample
    # in the cold run — the bytes the warm run never moves
    return [Binding("/split", "hpc", "svc"),
            Binding("/process", "hpc", "svc"),
            Binding("/train", "cloud", "svc"),
            Binding("/aggregate", "cloud", "svc")]


def _workflow() -> Workflow:
    """Deterministic 16-wide scatter chain; same builder, same args, same
    inputs => same memo keys across submissions."""
    import numpy as np
    wf = Workflow("cache-bench")

    def split(inputs, ctx):
        time.sleep(STEP_COST_S)
        base = int(inputs["seed"])
        return {"sample": [np.arange(64, dtype=np.float64) * (base + i + 1)
                           for i in range(N_SAMPLES)]}

    def process(inputs, ctx):
        time.sleep(STEP_COST_S)
        x = inputs["sample_in"]
        return {"feature": np.tile(x, FEATURE_FLOATS // x.size)}

    def train(inputs, ctx):
        time.sleep(STEP_COST_S)
        f = inputs["feature_in"]
        return {"model": float(f.sum()) / f.size}

    def aggregate(inputs, ctx):
        time.sleep(STEP_COST_S)
        return {"report": {"mean": sum(inputs["models"]) / N_SAMPLES,
                           "n": N_SAMPLES}}

    wf.add_step(Step("/split", split, {"seed": "seed"}, ("sample",),
                     streams={"sample": N_SAMPLES},
                     requirements=Requirements(cores=1)))
    wf.add_step(Step("/process", process, {"sample_in": "sample"},
                     ("feature",), scatter=("sample_in",),
                     requirements=Requirements(cores=1)))
    wf.add_step(Step("/train", train, {"feature_in": "feature"},
                     ("model",), scatter=("feature_in",),
                     requirements=Requirements(cores=1)))
    wf.add_step(Step("/aggregate", aggregate, {"models": "model"},
                     ("report",), gather=("models",),
                     requirements=Requirements(cores=1)))
    return wf


def _phase_row(phase: str, svc: WorkflowService, rid: str) -> dict:
    res = svc._runs[rid].result
    executed = sum(1 for e in res.events if e.status == "completed")
    memoized = sum(1 for e in res.events if e.status == "memoized")
    planned = 3 * N_SAMPLES + 2 - N_SAMPLES  # 1 + 16 + 16 + 1
    return {"phase": phase,
            "invocations": planned,
            "executed": executed,
            "memoized": memoized,
            "hit_rate": round(memoized / planned, 4),
            "makespan_s": round(res.wall_seconds, 3),
            "transfer_bytes": int(sum(r.bytes for r in res.transfers)),
            "cache_entries": len(svc.cache) if svc.cache else 0}


def _one_pair() -> list:
    tmp = tempfile.mkdtemp(prefix="sf-cache-bench-")
    svc = WorkflowService(
        _models(),
        service=ServiceConfig(max_concurrent=1, pool_enabled=True,
                              keepalive_s=60.0),
        cache=CacheConfig(index_path=os.path.join(tmp, "cache.jsonl"),
                          scope="service"),
        max_workers=2 * max(HPC_SLOTS, CLOUD_SLOTS),
        transfer_workers=4, deadlock_timeout_s=15.0)
    try:
        rows = []
        for phase in ("cold", "warm"):
            rid = svc.submit(_workflow(), _bindings(), {"seed": 7})
            info = svc.wait(rid, timeout=300)
            if info.state != "COMPLETE":
                raise RuntimeError(
                    f"{phase} run ended {info.state}: {info.error}")
            rows.append(_phase_row(phase, svc, rid))
        return rows
    finally:
        svc.close()
        shutil.rmtree(tmp, ignore_errors=True)


def run(verbose=True, repeats: int = REPEATS):
    # the hit counts are deterministic; only the wall ratio is noisy, so
    # take the median pair by warm/cold makespan ratio
    pairs = sorted((_one_pair() for _ in range(repeats)),
                   key=lambda p: p[1]["makespan_s"] / p[0]["makespan_s"])
    rows = pairs[len(pairs) // 2]

    if verbose:
        hdr = ["phase", "invocations", "executed", "memoized", "hit_rate",
               "makespan_s", "transfer_bytes", "cache_entries"]
        print(" | ".join(f"{h:>14s}" for h in hdr))
        for r in rows:
            print(" | ".join(f"{str(r[h]):>14s}" for h in hdr))
        cold, warm = rows
        print(f"\n[claim] warm re-run memoized {warm['memoized']}/"
              f"{warm['invocations']} invocations "
              f"(hit rate {warm['hit_rate']:.0%}); makespan "
              f"{cold['makespan_s']:.3f}s -> {warm['makespan_s']:.3f}s "
              f"({warm['makespan_s'] / max(cold['makespan_s'], 1e-9):.2f}x),"
              f" bytes {cold['transfer_bytes']} -> "
              f"{warm['transfer_bytes']}")
    return rows


def main(argv=None):
    run()


if __name__ == "__main__":
    main()
