"""Paper §4.4: scheduling-policy comparison on the single-cell workflow.

Data-locality (the paper's default) vs round-robin vs load-balance vs the
beyond-paper backfill.  Metric: remote transfers triggered (locality should
minimise them) + makespan.
"""
from __future__ import annotations

from repro.configs.paper_pipeline import streamflow_doc_single_service
from benchmarks.common import warmup, WF_ARGS, run_doc


POLICIES = ["data_locality", "round_robin", "load_balance", "backfill"]


def run(verbose=True):
    warmup()
    rows = []
    for policy in POLICIES:
        # one pool of private-store nodes: placement is the policy's choice
        doc = streamflow_doc_single_service(**WF_ARGS)
        doc["scheduling"]["policy"] = policy
        ex, res, wall = run_doc(doc)
        s = ex.data.transfer_summary()
        moved = sum(v["bytes"] for k, v in s.items()
                    if k in ("intra-model", "two-step"))
        rows.append({"policy": policy, "wall_s": round(wall, 3),
                     "remote_transfers": int(sum(
                         v["n"] for k, v in s.items()
                         if k in ("intra-model", "two-step"))),
                     "bytes_moved": int(moved),
                     "elided": int(s.get("elided", {}).get("n", 0))})
    if verbose:
        hdr = list(rows[0])
        print(" | ".join(f"{h:>18s}" for h in hdr))
        for r in rows:
            print(" | ".join(f"{str(r[h]):>18s}" for h in hdr))
        loc = rows[0]
        rr = rows[1]
        print(f"\n[claim] locality moves {loc['bytes_moved']:,} bytes vs "
              f"round-robin {rr['bytes_moved']:,} "
              f"({rr['bytes_moved'] / max(loc['bytes_moved'], 1):.1f}x)")
    return rows


def main(argv=None):
    run()


if __name__ == "__main__":
    main()
