"""Fault-tolerance drills (beyond-paper): overhead of surviving failures
and stragglers vs a clean run of the same workflow."""
from __future__ import annotations

from repro.core import FaultConfig
from repro.configs.paper_pipeline import streamflow_doc_full_hpc
from benchmarks.common import warmup, WF_ARGS, run_doc


def _doc(fail=None, straggle=None):
    doc = streamflow_doc_full_hpc(**WF_ARGS)
    if fail or straggle:
        inner = doc["models"]["occam"]
        doc["models"]["occam"] = {"type": "simcluster", "config": {
            "inner": {"type": "mesh", "config": inner["config"]},
            **({"fail": fail} if fail else {}),
            **({"straggle": straggle} if straggle else {}),
        }}
    return doc


def run(verbose=True):
    warmup()
    fault = FaultConfig(max_retries=2, backoff_s=0.02, speculative=True,
                        straggler_factor=2.5, straggler_min_samples=2,
                        straggler_min_elapsed_s=0.1)
    rows = []
    scenarios = [
        ("clean", _doc()),
        ("1-failure", _doc(fail=[{"match": "/chains/1/count",
                                  "attempts": [0]}])),
        ("straggler", _doc(straggle=[{"match": "/chains/2/seurat",
                                      "attempts": [0], "seconds": 3.0}])),
    ]
    for name, doc in scenarios:
        ex, res, wall = run_doc(doc, fault=fault)
        retries = len([e for e in res.events
                       if e.status.startswith("failed")])
        spec = len([e for e in res.events if e.speculative])
        rows.append({"scenario": name, "wall_s": round(wall, 2),
                     "failed_attempts": retries,
                     "speculative_twins": spec,
                     "completed": len([e for e in res.events
                                       if e.status == "completed"])})
    if verbose:
        hdr = list(rows[0])
        print(" | ".join(f"{h:>18s}" for h in hdr))
        for r in rows:
            print(" | ".join(f"{str(r[h]):>18s}" for h in hdr))
        clean, fail1, strag = rows
        print(f"\n[claim] workflow survives injected failure with "
              f"{fail1['wall_s'] / clean['wall_s']:.2f}x wall overhead; "
              f"speculation caps the straggler at "
              f"{strag['wall_s'] / clean['wall_s']:.2f}x "
              f"(injected delay was 3.0s)")
    return rows


def main(argv=None):
    run()


if __name__ == "__main__":
    main()
