"""Direct site-to-site routing vs the paper's two-step baseline (R3).

The paper's R3 rule relays every inter-model transfer through the
management node, so on the Fig. 9 hybrid topology (HPC site + cloud site,
no shared data space) the management link is a bandwidth bottleneck and a
makespan tax.  With a ``topology:`` block declaring a direct
occam <-> garr_cloud link, the DataManager's route planner sends the
shard/model tokens site-to-site and the management node only ever sees
the workflow's own inputs and collected outputs.

Both variants run the same workflow on the same simulated WAN numbers:

  management   routing="management" — the paper's two-step control;
               every cross-site hop pays the star link twice
  direct       routing="direct" — the planner uses the declared link

Reported per variant: makespan, bytes through the management node
(``DataManager.mgmt_bytes``), and the direct/two-step transfer counts.
``benchmarks/compare.py`` gates CI on the two claims: direct moves fewer
bytes through the management node AND finishes faster.
"""
from __future__ import annotations

from benchmarks.common import WF_ARGS, run_doc, warmup
from repro.configs.paper_pipeline import streamflow_doc_hybrid

# the Fig.9 WAN model: star edges are slow (the R3 tax), the declared
# site-to-site link is an order of magnitude cheaper on both terms
MGMT_LINK = {"latency_s": 0.08, "bandwidth_mbps": 100.0}
DIRECT_LINK = {"latency_s": 0.005, "bandwidth_mbps": 2000.0}
CLOUD_SLOTS = 2            # fewer cloud workers than chains => queue forms


def _doc(routing: str) -> dict:
    doc = streamflow_doc_hybrid(**WF_ARGS)
    doc["models"]["garr_cloud"]["config"]["services"]["r_env"][
        "replicas"] = CLOUD_SLOTS
    doc["topology"] = {
        "routing": routing,
        "management": dict(MGMT_LINK),
        "links": [{"source": "occam", "target": "garr_cloud",
                   **DIRECT_LINK}],
    }
    return doc


def _one(routing: str) -> dict:
    ex, res, wall = run_doc(_doc(routing))
    rows = res.timeline_rows()
    span = max(r[3] for r in rows) - min(r[2] for r in rows)
    summary = ex.data.transfer_summary()

    def _n(kind):
        return int(summary.get(kind, {}).get("n", 0))

    return {"mode": routing,
            "wall_s": round(wall, 3),
            "makespan_s": round(span, 3),
            "transfer_s": round(sum(r.seconds
                                    for r in ex.data.transfers), 3),
            "mgmt_bytes": ex.data.mgmt_bytes(),
            "direct_n": _n("direct"),
            "two_step_n": _n("two-step")}


def _median(runs) -> dict:
    runs = sorted(runs, key=lambda r: r["makespan_s"])
    return runs[len(runs) // 2]


def run(verbose=True, repeats: int = 3):
    warmup()
    # interleave the variants (A,B,A,B,...) so CPU-state drift over the
    # benchmark hits both modes equally; median-of-N per variant
    acc = {"management": [], "direct": []}
    for _ in range(repeats):
        for mode in acc:
            acc[mode].append(_one(mode))
    rows = [_median(runs) for runs in acc.values()]

    if verbose:
        hdr = ["mode", "wall_s", "makespan_s", "transfer_s", "mgmt_bytes",
               "direct_n", "two_step_n"]
        print(" | ".join(f"{h:>12s}" for h in hdr))
        for r in rows:
            print(" | ".join(f"{str(r[h]):>12s}" for h in hdr))
        by = {r["mode"]: r for r in rows}
        m, d = by["management"], by["direct"]
        print(f"\n[claim] Fig.9 hybrid: direct routing moves "
              f"{d['mgmt_bytes']} bytes through the management node vs "
              f"{m['mgmt_bytes']} for the two-step baseline "
              f"({m['mgmt_bytes'] / max(d['mgmt_bytes'], 1):.1f}x less) "
              f"and cuts makespan {m['makespan_s']:.3f}s -> "
              f"{d['makespan_s']:.3f}s "
            f"({m['makespan_s'] / max(d['makespan_s'], 1e-9):.2f}x)")
    return rows


def main(argv=None):
    run()


if __name__ == "__main__":
    main()
