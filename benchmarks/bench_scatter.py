"""N-sample scatter vs the hand-unrolled control on the Fig. 9 hybrid.

Two expressions of the same §5 workload, same WAN numbers, same worker
slots:

  hand-unrolled   ``build_workflow(n_chains=N)`` — one declared step per
                  chain (3N+1 steps), counts pinned to the HPC site: the
                  only way to write a wide run under the flat string-token
                  API, and the paper's Fig. 9 placement
  scatter         ``build_scatter_workflow(n_samples=N)`` — 5 declared
                  steps, width is one integer; the ``scatter:`` block
                  expands ``/count``/``/seurat``/``/singler`` into N
                  invocations each, and the ``/count`` binding targets
                  BOTH sites, so the scheduler places every invocation
                  individually

Reported per variant: makespan, scatter-width throughput (samples/s),
declared-DAG size vs executed invocations, distinct sites hosting count
work, and management-node bytes.  ``benchmarks/compare.py`` gates CI on
three claims: the scatter expression costs no makespan vs hand-unrolling
(its per-invocation placement may even win), one scatter really spreads
over >= 2 sites, and every planned invocation executes exactly once.
"""
from __future__ import annotations

from benchmarks.common import WF_ARGS, run_doc, warmup
from repro.configs.paper_pipeline import (streamflow_doc_hybrid,
                                          streamflow_doc_scatter_hybrid)

N_SAMPLES = 16
HPC_SLOTS = 4
CLOUD_SLOTS = 4
MGMT_LINK = {"latency_s": 0.08, "bandwidth_mbps": 100.0}
DIRECT_LINK = {"latency_s": 0.005, "bandwidth_mbps": 2000.0}


def _topology() -> dict:
    return {"routing": "direct", "management": dict(MGMT_LINK),
            "links": [{"source": "occam", "target": "garr_cloud",
                       **DIRECT_LINK}]}


def _doc_unrolled() -> dict:
    args = {k: v for k, v in WF_ARGS.items() if k != "n_chains"}
    doc = streamflow_doc_hybrid(n_chains=N_SAMPLES, **args)
    doc["models"]["occam"]["config"]["services"]["cellranger"][
        "replicas"] = HPC_SLOTS
    doc["models"]["garr_cloud"]["config"]["services"]["r_env"][
        "replicas"] = CLOUD_SLOTS
    doc["topology"] = _topology()
    return doc


def _doc_scatter() -> dict:
    doc = streamflow_doc_scatter_hybrid(
        n_samples=N_SAMPLES, hpc_replicas=HPC_SLOTS,
        cloud_replicas=CLOUD_SLOTS,
        rows_per_sample=WF_ARGS["rows_per_chain"],
        seq_len=WF_ARGS["seq_len"], train_steps=WF_ARGS["train_steps"],
        batch=WF_ARGS["batch"], vocab=WF_ARGS["vocab"],
        d_model=WF_ARGS["d_model"])
    doc["topology"] = _topology()
    return doc


def _count_step(step: str) -> bool:
    return step.startswith("/count") or "/count" in step


def _one(mode: str) -> dict:
    doc = _doc_scatter() if mode == "scatter" else _doc_unrolled()
    ex, res, wall = run_doc(doc)
    rows = res.timeline_rows()
    span = max(r[3] for r in rows) - min(r[2] for r in rows)
    done = [e for e in res.events if e.status == "completed"]
    declared = (5 if mode == "scatter" else 3 * N_SAMPLES + 1)
    planned = (3 * N_SAMPLES + 2 if mode == "scatter"
               else 3 * N_SAMPLES + 1)
    # per-port accounting: in scatter mode the heavy "model" stream groups
    # its element transfers under one port; the unrolled control smears
    # them over N distinct token names (model0..modelN-1)
    ports = ex.data.port_summary()
    model_ports = {p: s for p, s in ports.items() if p.startswith("model")}
    return {"mode": mode,
            "model_port_names": len(model_ports),
            "model_bytes": int(sum(s["bytes"]
                                   for s in model_ports.values())),
            "width": N_SAMPLES,
            "declared_steps": declared,
            "planned": planned,
            "invocations": len(done),
            "makespan_s": round(span, 3),
            "throughput_sps": round(N_SAMPLES / max(span, 1e-9), 3),
            "count_sites": len({e.model for e in done
                                if _count_step(e.step)}),
            "mgmt_bytes": ex.data.mgmt_bytes(),
            "direct_n": int(ex.data.transfer_summary().get(
                "direct", {}).get("n", 0))}


def _median(runs):
    runs = sorted(runs, key=lambda r: r["makespan_s"])
    return runs[len(runs) // 2]


def run(verbose=True, repeats: int = 3):
    warmup()
    # interleave variants so CPU-state drift hits both equally
    acc = {"hand-unrolled": [], "scatter": []}
    for _ in range(repeats):
        for mode in acc:
            acc[mode].append(_one(mode))
    rows = [_median(runs) for runs in acc.values()]

    if verbose:
        hdr = ["mode", "width", "declared_steps", "invocations",
               "makespan_s", "throughput_sps", "count_sites", "mgmt_bytes",
               "model_port_names"]
        print(" | ".join(f"{h:>14s}" for h in hdr))
        for r in rows:
            print(" | ".join(f"{str(r[h]):>14s}" for h in hdr))
        by = {r["mode"]: r for r in rows}
        u, s = by["hand-unrolled"], by["scatter"]
        print(f"\n[claim] {N_SAMPLES}-sample pipeline: {u['declared_steps']}"
              f" hand-unrolled steps vs {s['declared_steps']} declared "
              f"scatter steps ({s['invocations']} invocations executed); "
              f"makespan {u['makespan_s']:.3f}s -> {s['makespan_s']:.3f}s "
              f"({s['makespan_s'] / max(u['makespan_s'], 1e-9):.2f}x), "
              f"count invocations spread over {s['count_sites']} sites")
    return rows


def main(argv=None):
    run()


if __name__ == "__main__":
    main()
