"""Benchmark-regression gate: diff a CI ``bench.json`` against the
committed ``benchmarks/baseline.json`` and FAIL on regression.

Raw wall-clock numbers on shared CI runners are too noisy to gate on, so
every gated metric is *self-normalizing* — a ratio between two variants
measured in the same process (pipelined vs serialized makespan, direct vs
two-step routing) or a deterministic structural count (bytes through the
management node, number of direct transfers).  Each metric carries:

  * a committed baseline value (``benchmarks/baseline.json``),
  * a relative tolerance — how much worse than baseline is still noise,
  * an optional hard bound — the claim itself (e.g. "direct routing must
    move fewer bytes through the management node"), enforced regardless
    of what the baseline says.

Usage:
  python benchmarks/compare.py bench.json                # gate (CI)
  python benchmarks/compare.py bench.json --write-baseline
                                                         # refresh baseline

Exit codes: 0 = pass, 1 = regression / missing metric / unreadable input.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

HERE = os.path.dirname(os.path.abspath(__file__))
DEFAULT_BASELINE = os.path.join(HERE, "baseline.json")


def _rows_by(results: dict, bench: str, key: str) -> Dict[str, dict]:
    rows = results.get(bench)
    if rows is None:
        raise KeyError(f"bench.json has no results for {bench!r} "
                       f"(was it in --only?)")
    return {r[key]: r for r in rows}


def _pipeline_speedup(results: dict) -> float:
    """Serialized FCFS over pipelined makespan on the Fig.9 hybrid —
    the PR-2 claim that pipelining hides the R3 transfer tax."""
    fig9 = {r["mode"]: r for r in results["pipeline_makespan"]
            if r.get("topology") == "fig9"}
    return (fig9["serialized-fcfs"]["makespan_s"]
            / max(fig9["pipelined"]["makespan_s"], 1e-9))


def _recovery_speedup(results: dict) -> float:
    """From-scratch over resumed makespan — the PR-3 claim that journal
    recovery re-executes only the lost frontier.  Wall-sensitive (the
    absolute value swings with machine load), so only the hard bound
    carries weight; the structural claim lives in _recovery_steps_ratio."""
    by = _rows_by(results, "recovery_makespan", "phase")
    return (by["from-scratch"]["makespan_s"]
            / max(by["resumed"]["makespan_s"], 1e-9))


def _recovery_steps_ratio(results: dict) -> float:
    """Share of the workflow's steps the resumed run re-executed —
    deterministic (the crash point is fixed), unlike the wall ratio.
    1.0 would mean resume recomputed everything."""
    by = _rows_by(results, "recovery_makespan", "phase")
    return (by["resumed"]["steps_executed"]
            / max(by["from-scratch"]["steps_executed"], 1))


def _routing_makespan_ratio(results: dict) -> float:
    """Direct over management-routed makespan — the PR-4 claim that the
    topology planner beats the two-step baseline.  Lower is better."""
    by = _rows_by(results, "routing_data_plane", "mode")
    return (by["direct"]["makespan_s"]
            / max(by["management"]["makespan_s"], 1e-9))


def _routing_mgmt_bytes_ratio(results: dict) -> float:
    """Share of the baseline's management-node bytes that direct routing
    still moves through the star.  Lower is better; structural, so the
    hard bound is tight."""
    by = _rows_by(results, "routing_data_plane", "mode")
    return (by["direct"]["mgmt_bytes"]
            / max(by["management"]["mgmt_bytes"], 1))


def _routing_direct_transfers(results: dict) -> float:
    """Direct transfers actually executed — zero means the planner never
    took the declared link and the feature is silently off."""
    by = _rows_by(results, "routing_data_plane", "mode")
    return float(by["direct"]["direct_n"])


def _scatter_makespan_ratio(results: dict) -> float:
    """Scatter over hand-unrolled makespan on the Fig.9 hybrid — the PR-5
    claim that the Port/Token scatter expression costs nothing vs
    unrolling the DAG by hand (its per-invocation multi-site placement
    may even win).  Lower is better."""
    by = _rows_by(results, "scatter_width", "mode")
    return (by["scatter"]["makespan_s"]
            / max(by["hand-unrolled"]["makespan_s"], 1e-9))


def _scatter_count_sites(results: dict) -> float:
    """Distinct sites that hosted /count invocations in scatter mode —
    below 2 means one declared scatter no longer spreads across the
    hybrid and per-invocation placement is silently off."""
    by = _rows_by(results, "scatter_width", "mode")
    return float(by["scatter"]["count_sites"])


def _scatter_invocations_ratio(results: dict) -> float:
    """Executed over planned invocations in scatter mode — deterministic;
    anything but 1.0 means the expansion lost or duplicated work."""
    by = _rows_by(results, "scatter_width", "mode")
    return (by["scatter"]["invocations"]
            / max(by["scatter"]["planned"], 1))


def _service_throughput_ratio(results: dict) -> float:
    """Pooled over per-run service throughput under the same bursty
    arrivals — the PR-6 claim that the deployment pool amortizes site
    bring-up across runs.  Higher is better."""
    by = _rows_by(results, "service_multitenant", "variant")
    return (by["pooled"]["throughput_rps"]
            / max(by["per-run"]["throughput_rps"], 1e-9))


def _service_p99_ratio(results: dict) -> float:
    """Pooled over per-run steady-state p99 run latency — with a warm
    pool a run never waits on site bring-up, so its tail must sit far
    below the per-run control's.  Lower is better."""
    by = _rows_by(results, "service_multitenant", "variant")
    return (by["pooled"]["lat_p99_s"]
            / max(by["per-run"]["lat_p99_s"], 1e-9))


def _cache_warm_makespan_ratio(results: dict) -> float:
    """Warm (fully memoized) over cold makespan on the 16-wide scatter —
    the PR-7 claim that a verified cache hit skips the invocation's
    compute AND its data movement.  Lower is better; the hard bound is
    the acceptance criterion (warm at most half of cold; in practice
    ~0.1x)."""
    by = _rows_by(results, "cache_memoization", "phase")
    return (by["warm"]["makespan_s"]
            / max(by["cold"]["makespan_s"], 1e-9))


def _cache_bytes_ratio(results: dict) -> float:
    """Warm over cold transfer-log bytes — structural: a memoized run
    aliases payloads by digest instead of copying them, so it moves only
    the final output collection.  Lower is better."""
    by = _rows_by(results, "cache_memoization", "phase")
    return (by["warm"]["transfer_bytes"]
            / max(by["cold"]["transfer_bytes"], 1))


def _autoscale_makespan_ratio(results: dict) -> float:
    """Elastic over static makespan on the same serialized batch — the
    PR-9 claim that queue-pressure scale-up genuinely grows the pool and
    beats the one-slot control.  Lower is better."""
    by = _rows_by(results, "autoscale_elasticity", "mode")
    return (by["elastic"]["makespan_s"]
            / max(by["static"]["makespan_s"], 1e-9))


def _autoscale_wasted_work_ratio(results: dict) -> float:
    """Attempts lost to spot revocations per useful invocation in the
    preempted run — the PR-9 claim that preemption waste stays bounded
    (each revocation costs at most the attempts in flight on the revoked
    site; retries land on survivors).  Lower is better."""
    by = _rows_by(results, "autoscale_elasticity", "mode")
    return (by["preempted"]["wasted_invocations"]
            / max(by["preempted"]["useful_invocations"], 1))


def _analyze_lb_ratio_unrolled(results: dict) -> float:
    """Measured over statically predicted makespan on the hand-unrolled
    Fig. 9 hybrid — the PR-10 bracket: >= 1 means the analyzer's lower
    bound is sound (it never promised more than the run delivered), <= 3
    means the prediction is tight enough to rank placements with."""
    by = _rows_by(results, "analyze_prediction", "mode")
    return by["hand-unrolled"]["ratio"]


def _analyze_lb_ratio_scatter(results: dict) -> float:
    """The same bracket on the scatter expression of the pipeline, where
    the analyzer must reason through scatter widths and the joint slot
    bound instead of a step-per-chain DAG."""
    by = _rows_by(results, "analyze_prediction", "mode")
    return by["scatter"]["ratio"]


def _cache_hit_rate(results: dict) -> float:
    """Share of the warm run's invocations satisfied from the cache —
    deterministic (same workflow, same inputs, live pooled sites); below
    0.9 means memo keys or verification silently broke."""
    by = _rows_by(results, "cache_memoization", "phase")
    return (by["warm"]["memoized"]
            / max(by["warm"]["invocations"], 1))


@dataclass
class Metric:
    name: str
    extract: Callable[[dict], float]
    higher_is_better: bool
    rel_tol: float                  # fractional drift vs baseline == noise
    hard_min: Optional[float] = None   # the claim itself, baseline-independent
    hard_max: Optional[float] = None

    def check(self, value: float, baseline: Optional[float]) -> List[str]:
        errs = []
        if self.hard_min is not None and value < self.hard_min:
            errs.append(f"hard bound: {value:.4g} < min {self.hard_min}")
        if self.hard_max is not None and value > self.hard_max:
            errs.append(f"hard bound: {value:.4g} > max {self.hard_max}")
        if baseline is not None:
            if self.higher_is_better:
                floor = baseline * (1.0 - self.rel_tol)
                if value < floor:
                    errs.append(f"regressed vs baseline {baseline:.4g} "
                                f"(floor {floor:.4g})")
            else:
                ceil = baseline * (1.0 + self.rel_tol)
                if value > ceil:
                    errs.append(f"regressed vs baseline {baseline:.4g} "
                                f"(ceiling {ceil:.4g})")
        return errs


# Tolerances are generous because CI runners differ from the machine that
# wrote the baseline (core count changes how much compute there is to hide
# transfers behind); the hard bounds carry the actual claims and never
# loosen with the baseline.
METRICS = [
    Metric("pipeline_fig9_speedup", _pipeline_speedup,
           higher_is_better=True, rel_tol=0.35, hard_min=1.0),
    # wall ratio: hard bound only in practice (rel_tol spans the quiet-
    # vs-contended-machine spread); the steps ratio is the tight check
    Metric("recovery_speedup", _recovery_speedup,
           higher_is_better=True, rel_tol=0.95, hard_min=1.15),
    # the crash fires on a completion-count threshold, so the exact number
    # of in-flight steps lost with the driver wobbles by a couple
    Metric("recovery_steps_ratio", _recovery_steps_ratio,
           higher_is_better=False, rel_tol=0.40, hard_max=0.95),
    Metric("routing_makespan_ratio", _routing_makespan_ratio,
           higher_is_better=False, rel_tol=0.25, hard_max=0.97),
    Metric("routing_mgmt_bytes_ratio", _routing_mgmt_bytes_ratio,
           higher_is_better=False, rel_tol=0.50, hard_max=0.10),
    Metric("routing_direct_transfers", _routing_direct_transfers,
           higher_is_better=True, rel_tol=0.50, hard_min=1.0),
    Metric("scatter_makespan_ratio", _scatter_makespan_ratio,
           higher_is_better=False, rel_tol=0.30, hard_max=1.25),
    # structural: the scatter must really spread and really run everything
    Metric("scatter_count_sites", _scatter_count_sites,
           higher_is_better=True, rel_tol=0.0, hard_min=2.0),
    Metric("scatter_invocations_ratio", _scatter_invocations_ratio,
           higher_is_better=True, rel_tol=0.0,
           hard_min=1.0, hard_max=1.0),
    # wall-ratio between the two service variants in one process; the
    # hard bound is the claim (pooling must not LOSE throughput)
    Metric("service_throughput_ratio", _service_throughput_ratio,
           higher_is_better=True, rel_tol=0.30, hard_min=1.05),
    # steady-state tail latency: the pooled p99 swings with scheduler
    # timing (it is tiny in absolute terms), so the tolerance is wide —
    # the hard bound pins the claim (pooled tail at most half the
    # per-run control's)
    Metric("service_p99_ratio", _service_p99_ratio,
           higher_is_better=False, rel_tol=4.0, hard_max=0.5),
    # warm/cold wall ratio in one process: the hard bound is the PR-7
    # acceptance criterion; the wide tolerance absorbs the tiny absolute
    # warm makespan swinging with scheduler timing
    Metric("cache_warm_makespan_ratio", _cache_warm_makespan_ratio,
           higher_is_better=False, rel_tol=3.0, hard_max=0.5),
    # structural: warm bytes are one small report collection vs the cold
    # run's megabytes of input/feature movement
    Metric("cache_bytes_ratio", _cache_bytes_ratio,
           higher_is_better=False, rel_tol=1.0, hard_max=0.05),
    Metric("cache_hit_rate", _cache_hit_rate,
           higher_is_better=True, rel_tol=0.0, hard_min=0.9),
    # elastic/static wall in one process: the hard bound is the claim
    # (scale-up must beat the one-slot control); with 4-way replicas the
    # ratio sits near 1/4 plus scale-up latency on a quiet machine
    Metric("autoscale_makespan_ratio", _autoscale_makespan_ratio,
           higher_is_better=False, rel_tol=0.50, hard_max=0.80),
    # structural-ish: N_PREEMPTS revocations, each wasting at most the
    # attempts in flight on the revoked replica — far below one wasted
    # attempt per useful invocation
    Metric("autoscale_wasted_work_ratio", _autoscale_wasted_work_ratio,
           higher_is_better=False, rel_tol=1.0, hard_max=0.5),
    # measured/predicted makespan: the hard bounds ARE the claim (sound
    # lower bound, usefully tight); the ratio is self-normalizing because
    # the per-step costs are calibrated from the very run being measured
    Metric("analyze_lb_ratio_unrolled", _analyze_lb_ratio_unrolled,
           higher_is_better=False, rel_tol=0.8, hard_min=1.0,
           hard_max=3.0),
    Metric("analyze_lb_ratio_scatter", _analyze_lb_ratio_scatter,
           higher_is_better=False, rel_tol=0.8, hard_min=1.0,
           hard_max=3.0),
]


def extract_metrics(bench: dict) -> Dict[str, float]:
    results = bench.get("results", {})
    out = {}
    for m in METRICS:
        out[m.name] = round(float(m.extract(results)), 6)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench_json", help="the CI run's bench.json "
                    "(benchmarks.run --json output)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"committed baseline (default {DEFAULT_BASELINE})")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the extracted metrics to --baseline "
                    "instead of gating against it")
    args = ap.parse_args(argv)

    with open(args.bench_json, encoding="utf-8") as fh:
        bench = json.load(fh)
    try:
        metrics = extract_metrics(bench)
    except KeyError as e:
        print(f"FAIL cannot extract metrics: {e}", file=sys.stderr)
        return 1

    if args.write_baseline:
        with open(args.baseline, "w", encoding="utf-8") as fh:
            json.dump({"generated_unix": time.time(),
                       "source": os.path.basename(args.bench_json),
                       "metrics": metrics}, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.baseline}")
        for name, value in metrics.items():
            print(f"  {name} = {value}")
        return 0

    try:
        with open(args.baseline, encoding="utf-8") as fh:
            committed = json.load(fh)["metrics"]
    except (OSError, KeyError, ValueError) as e:
        print(f"FAIL unreadable baseline {args.baseline}: {e}",
              file=sys.stderr)
        return 1

    failures = []
    width = max(len(m.name) for m in METRICS)
    for m in METRICS:
        value = metrics[m.name]
        base = committed.get(m.name)
        errs = m.check(value, base)
        arrow = "↑" if m.higher_is_better else "↓"
        status = "ok " if not errs else "FAIL"
        print(f"{status} {m.name:<{width}s} {arrow} value={value:<10.4g} "
              f"baseline={base if base is not None else 'n/a'}")
        if base is None:
            # a metric without a committed baseline means someone added a
            # metric but forgot to refresh baseline.json — fail loudly
            errs.append("no committed baseline (run --write-baseline)")
        for e in errs:
            failures.append(f"{m.name}: {e}")
            print(f"     {e}")

    if failures:
        print(f"\n{len(failures)} regression check(s) failed",
              file=sys.stderr)
        return 1
    print("\nall benchmark-regression checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
