"""Static cost prediction (SF3xx analyzer) vs measured makespan.

The analyzer's cost engine promises a *lower bound*: with per-step cost
estimates that are themselves not overestimates, the predicted
``makespan_lower_bound_s`` never exceeds what a real run measures.  This
bench closes the loop on the two §5 expressions of the hybrid pipeline
(the hand-unrolled Fig. 9 document and its scatter twin, the exact docs
bench_scatter races):

1. run the document and measure the timeline span;
2. calibrate per-declared-step costs from that run — the MINIMUM
   invocation duration per declared step, an optimistic per-step cost by
   construction, so machine speed cancels out of the comparison;
3. feed those costs to ``analyzer.analyze`` and compare its predicted
   lower bound against the measured span.

``benchmarks/compare.py`` gates CI on the bracket both ways: predicted
<= measured (soundness — the bound is real) and measured <= 3x predicted
(tightness — the prediction is close enough to be useful for placement
decisions, not a vacuous zero).
"""
from __future__ import annotations

from benchmarks.bench_scatter import _doc_scatter, _doc_unrolled
from benchmarks.common import run_doc, warmup
from repro.core import load_streamflow_file
from repro.core.analyzer import analyze


def _calibrated_costs(rows) -> dict:
    """Declared step path -> min completed invocation duration (s)."""
    costs: dict = {}
    for step, _resource, t0, t1, status, _attempt, _spec in rows:
        if not status.startswith("completed"):
            continue
        declared = step.split("@")[0]
        dur = max(t1 - t0, 0.0)
        if declared not in costs or dur < costs[declared]:
            costs[declared] = dur
    return costs


def _one(mode: str) -> dict:
    doc = _doc_scatter() if mode == "scatter" else _doc_unrolled()
    cfg = load_streamflow_file(doc)
    _ex, res, _wall = run_doc(doc)
    rows = res.timeline_rows()
    measured = max(r[3] for r in rows) - min(r[2] for r in rows)

    report = analyze(cfg, step_costs=_calibrated_costs(rows),
                     default_cost_s=0.0)
    wname = next(iter(cfg.workflows))
    cost = report.cost[wname]
    predicted = cost["makespan_lower_bound_s"]
    return {"mode": mode,
            "invocations": cost["n_invocations"],
            "errors": len(report.errors()),
            "warnings": len(report.warnings()),
            "predicted_lb_s": round(predicted, 4),
            "critical_path_s": round(cost["critical_path_s"], 4),
            "total_work_s": round(cost["total_work_s"], 4),
            "max_parallel_slots": cost["max_parallel_slots"],
            "measured_s": round(measured, 4),
            "ratio": round(measured / max(predicted, 1e-9), 4)}


def _median(runs):
    runs = sorted(runs, key=lambda r: r["ratio"])
    return runs[len(runs) // 2]


def run(verbose=True, repeats: int = 3):
    warmup()
    acc = {"hand-unrolled": [], "scatter": []}
    for _ in range(repeats):
        for mode in acc:                  # interleave against CPU drift
            acc[mode].append(_one(mode))
    rows = [_median(runs) for runs in acc.values()]

    if verbose:
        hdr = ["mode", "invocations", "predicted_lb_s", "critical_path_s",
               "total_work_s", "max_parallel_slots", "measured_s", "ratio"]
        print(" | ".join(f"{h:>17s}" for h in hdr))
        for r in rows:
            print(" | ".join(f"{str(r[h]):>17s}" for h in hdr))
        for r in rows:
            print(f"[claim] {r['mode']}: predicted lower bound "
                  f"{r['predicted_lb_s']:.3f}s <= measured "
                  f"{r['measured_s']:.3f}s <= 3x prediction "
                  f"(ratio {r['ratio']:.2f}x)")
    return rows
