"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus each benchmark's own
detailed output above them).  Wall-clock numbers on this CPU container are
structural (ordering / counts / overlap), not TPU timings; the TPU-facing
performance analysis lives in launch/roofline.py + EXPERIMENTS.md.

``--only a b`` runs a subset; ``--json out.json`` additionally writes the
summary rows plus each benchmark's raw result rows to a JSON file (CI
uploads this as a workflow artifact).
"""
from __future__ import annotations

import argparse
import json
import time


def _timed(fn, *a, **kw):
    t0 = time.time()
    out = fn(*a, **kw)
    return out, (time.time() - t0) * 1e6


def _sections():
    from benchmarks import (bench_analyze, bench_autoscale, bench_cache,
                            bench_deployment, bench_fault, bench_pipeline,
                            bench_recovery, bench_routing, bench_scatter,
                            bench_scheduler, bench_service, bench_timeline,
                            bench_transfer)

    def timeline():
        out, us = _timed(bench_timeline.run, "both")
        hybrid = out.get("hybrid (Fig.9)", {})
        full = out.get("full-hpc (Fig.8)", {})
        derived = (f"hybrid/full_wall="
                   f"{hybrid.get('wall_s', 0) / max(full.get('wall_s', 1), 1e-9):.2f};"
                   f"transfer_frac={hybrid.get('transfer_frac', 0):.4f}")
        return out, us, derived

    def transfer():
        out, us = _timed(bench_transfer.run)
        big = out[-2]
        return out, us, (f"two_step_32MiB={big['two_step_s']:.4f}s;"
                         f"elided={big['elided_s']:.5f}s")

    def scheduler():
        out, us = _timed(bench_scheduler.run)
        return out, us, ";".join(f"{r['policy']}={r['bytes_moved']}"
                                 for r in out)

    def deployment():
        out, us = _timed(bench_deployment.run)
        return out, us, ";".join(f"{r['strategy']}={r['site_s']}"
                                 for r in out)

    def fault():
        out, us = _timed(bench_fault.run)
        return out, us, ";".join(f"{r['scenario']}={r['wall_s']}"
                                 for r in out)

    def pipeline():
        out, us = _timed(bench_pipeline.run)
        fig9 = {r["mode"]: r for r in out if r["topology"] == "fig9"}
        return out, us, (f"serial={fig9['serialized-fcfs']['makespan_s']}s;"
                         f"pipelined={fig9['pipelined']['makespan_s']}s")

    def recovery():
        out, us = _timed(bench_recovery.run)
        by = {r["phase"]: r for r in out}
        return out, us, (f"scratch={by['from-scratch']['makespan_s']}s;"
                         f"resumed={by['resumed']['makespan_s']}s")

    def routing():
        out, us = _timed(bench_routing.run)
        by = {r["mode"]: r for r in out}
        return out, us, (f"mgmt_bytes={by['management']['mgmt_bytes']}"
                         f"->{by['direct']['mgmt_bytes']};"
                         f"makespan={by['management']['makespan_s']}s"
                         f"->{by['direct']['makespan_s']}s")

    def service():
        out, us = _timed(bench_service.run)
        by = {r["variant"]: r for r in out}
        return out, us, (f"throughput={by['per-run']['throughput_rps']}"
                         f"->{by['pooled']['throughput_rps']}rps;"
                         f"p99={by['per-run']['lat_p99_s']}s"
                         f"->{by['pooled']['lat_p99_s']}s;"
                         f"deploys={by['per-run']['deploys']}"
                         f"->{by['pooled']['deploys']}")

    def cache():
        out, us = _timed(bench_cache.run)
        by = {r["phase"]: r for r in out}
        return out, us, (f"hit_rate={by['warm']['hit_rate']};"
                         f"makespan={by['cold']['makespan_s']}s"
                         f"->{by['warm']['makespan_s']}s;"
                         f"bytes={by['cold']['transfer_bytes']}"
                         f"->{by['warm']['transfer_bytes']}")

    def autoscale():
        out, us = _timed(bench_autoscale.run)
        by = {r["mode"]: r for r in out}
        return out, us, (f"makespan={by['static']['makespan_s']}s"
                         f"->{by['elastic']['makespan_s']}s;"
                         f"scale_ups={by['elastic']['scale_ups']};"
                         f"wasted={by['preempted']['wasted_invocations']}"
                         f"/{by['preempted']['useful_invocations']}")

    def analyze():
        out, us = _timed(bench_analyze.run)
        by = {r["mode"]: r for r in out}
        return out, us, (f"unrolled={by['hand-unrolled']['predicted_lb_s']}s"
                         f"<={by['hand-unrolled']['measured_s']}s"
                         f"({by['hand-unrolled']['ratio']}x);"
                         f"scatter={by['scatter']['predicted_lb_s']}s"
                         f"<={by['scatter']['measured_s']}s"
                         f"({by['scatter']['ratio']}x)")

    def scatter():
        out, us = _timed(bench_scatter.run)
        by = {r["mode"]: r for r in out}
        return out, us, (f"unrolled={by['hand-unrolled']['makespan_s']}s;"
                         f"scatter={by['scatter']['makespan_s']}s;"
                         f"sites={by['scatter']['count_sites']};"
                         f"invocations={by['scatter']['invocations']}")

    return [
        ("fig8_fig9_timeline", "bench_timeline — paper Fig.8/Fig.9 "
         "(full-HPC vs hybrid)", timeline),
        ("transfer_strategies", "bench_transfer — §4.6 R3/R4 transfer "
         "strategies", transfer),
        ("scheduler_policies", "bench_scheduler — §4.4 policies", scheduler),
        ("deployment_lifecycle", "bench_deployment — §4.5 lifecycle "
         "strategies", deployment),
        ("fault_drills", "bench_fault — failure/straggler drills "
         "(beyond-paper)", fault),
        ("pipeline_makespan", "bench_pipeline — serialized FCFS vs "
         "pipelined executor", pipeline),
        ("recovery_makespan", "bench_recovery — journal crash-recovery vs "
         "from-scratch", recovery),
        ("routing_data_plane", "bench_routing — direct site-to-site "
         "routing vs the R3 two-step baseline", routing),
        ("scatter_width", "bench_scatter — N-sample scatter vs the "
         "hand-unrolled control", scatter),
        ("analyze_prediction", "bench_analyze — static makespan lower "
         "bound vs measured (SF3xx cost engine)", analyze),
        ("service_multitenant", "bench_service — pooled vs per-run "
         "deployments under bursty multi-tenant load", service),
        ("cache_memoization", "bench_cache — cross-run invocation "
         "memoization: warm re-run vs cold", cache),
        ("autoscale_elasticity", "bench_autoscale — elastic replicas vs "
         "static pool, plus spot preemption waste", autoscale),
    ]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", nargs="+", metavar="NAME",
                    help="run only these benchmarks (by summary-row name, "
                    "substring match allowed)")
    ap.add_argument("--json", metavar="PATH",
                    help="write summary + raw rows to this JSON file")
    args = ap.parse_args(argv)

    sections = _sections()
    if args.only:
        names = [name for name, _, _ in sections]
        dead = [sel for sel in args.only
                if not any(sel in n for n in names)]
        if dead:   # a typo'd selector must not yield a green empty run
            ap.error(f"--only selector(s) {dead} match no benchmark; "
                     f"known: {names}")

    rows = []
    raw = {}
    for name, title, runner in sections:
        if args.only and not any(sel in name for sel in args.only):
            continue
        print("=" * 72)
        print(title)
        print("=" * 72)
        out, us, derived = runner()
        rows.append((name, us, derived))
        raw[name] = out
        print()

    print("=" * 72)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")

    if args.json:
        with open(args.json, "w", encoding="utf-8") as fh:
            json.dump({"generated_unix": time.time(),
                       "summary": [{"name": n, "us_per_call": round(us),
                                    "derived": d} for n, us, d in rows],
                       "results": raw}, fh, indent=2, default=str)
        print(f"\nwrote {args.json}")


if __name__ == "__main__":
    main()
