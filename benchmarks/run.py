"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus each benchmark's own
detailed output above them).  Wall-clock numbers on this CPU container are
structural (ordering / counts / overlap), not TPU timings; the TPU-facing
performance analysis lives in launch/roofline.py + EXPERIMENTS.md.
"""
from __future__ import annotations

import time


def _timed(fn, *a, **kw):
    t0 = time.time()
    out = fn(*a, **kw)
    return out, (time.time() - t0) * 1e6


def main() -> None:
    from benchmarks import (bench_timeline, bench_transfer, bench_scheduler,
                            bench_deployment, bench_fault, bench_pipeline)
    rows = []

    print("=" * 72)
    print("bench_timeline — paper Fig.8/Fig.9 (full-HPC vs hybrid)")
    print("=" * 72)
    out, us = _timed(bench_timeline.run, "both")
    hybrid = out.get("hybrid (Fig.9)", {})
    full = out.get("full-hpc (Fig.8)", {})
    rows.append(("fig8_fig9_timeline", us,
                 f"hybrid/full_wall={hybrid.get('wall_s', 0) / max(full.get('wall_s', 1), 1e-9):.2f};"
                 f"transfer_frac={hybrid.get('transfer_frac', 0):.4f}"))

    print("\n" + "=" * 72)
    print("bench_transfer — §4.6 R3/R4 transfer strategies")
    print("=" * 72)
    out, us = _timed(bench_transfer.run)
    big = out[-2]
    rows.append(("transfer_strategies", us,
                 f"two_step_32MiB={big['two_step_s']:.4f}s;"
                 f"elided={big['elided_s']:.5f}s"))

    print("\n" + "=" * 72)
    print("bench_scheduler — §4.4 policies")
    print("=" * 72)
    out, us = _timed(bench_scheduler.run)
    rows.append(("scheduler_policies", us,
                 ";".join(f"{r['policy']}={r['bytes_moved']}" for r in out)))

    print("\n" + "=" * 72)
    print("bench_deployment — §4.5 lifecycle strategies")
    print("=" * 72)
    out, us = _timed(bench_deployment.run)
    rows.append(("deployment_lifecycle", us,
                 ";".join(f"{r['strategy']}={r['site_s']}" for r in out)))

    print("\n" + "=" * 72)
    print("bench_fault — failure/straggler drills (beyond-paper)")
    print("=" * 72)
    out, us = _timed(bench_fault.run)
    rows.append(("fault_drills", us,
                 ";".join(f"{r['scenario']}={r['wall_s']}" for r in out)))

    print("\n" + "=" * 72)
    print("bench_pipeline — serialized FCFS vs pipelined executor")
    print("=" * 72)
    out, us = _timed(bench_pipeline.run)
    fig9 = {r["mode"]: r for r in out if r["topology"] == "fig9"}
    rows.append(("pipeline_makespan", us,
                 f"serial={fig9['serialized-fcfs']['makespan_s']}s;"
                 f"pipelined={fig9['pipelined']['makespan_s']}s"))

    print("\n" + "=" * 72)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")


if __name__ == "__main__":
    main()
