"""Serialized FCFS vs the pipelined executor on the paper's topologies.

The paper's loop (§4.4) schedules one task at a time and moves each task's
tokens synchronously before it computes.  The pipelined executor schedules
the whole ready queue per tick, issues transfers asynchronously (token
movement for step N+1 overlaps compute of step N) and stages inputs onto
slot-starved sites ahead of time.  This benchmark measures the makespan gap
on:

  fig8   — full-HPC (one shared-store site): the win comes from batch
           scheduling + event-driven wakeup (no WAN hops to hide);
  fig9   — hybrid HPC+cloud with NO shared data space and a simulated WAN
           link between the sites and the management node, with fewer cloud
           slots than chains: the pipelined run hides the R3 two-step
           copies behind compute, the serialized run pays them in-line.

Also compares the queue-aware policies (backfill / locality_batch /
widest_first, beyond-paper) against plain data-locality in pipelined mode.
"""
from __future__ import annotations

from benchmarks.common import WF_ARGS, run_doc, warmup
from repro.configs.paper_pipeline import (streamflow_doc_full_hpc,
                                          streamflow_doc_hybrid)

# WAN model for fig9: each management<->site hop costs 50 ms + payload time,
# so an R3 two-step copy (site -> mgmt -> site) costs >= 100 ms
LINK = {"link_latency_s": 0.05, "link_bandwidth_mbps": 200.0}
CLOUD_SLOTS = 2            # fewer cloud workers than chains => queue forms

QUEUE_POLICIES = ["data_locality", "backfill", "locality_batch",
                  "widest_first"]


def _fig8_doc():
    return streamflow_doc_full_hpc(**WF_ARGS)


def _fig9_doc():
    doc = streamflow_doc_hybrid(**WF_ARGS)
    for model in doc["models"].values():
        model["config"].update(LINK)
    doc["models"]["garr_cloud"]["config"]["services"]["r_env"][
        "replicas"] = CLOUD_SLOTS
    return doc


def _one(doc_fn, **kw) -> dict:
    ex, res, wall = run_doc(doc_fn(), **kw)
    rows = res.timeline_rows()
    span = max(r[3] for r in rows) - min(r[2] for r in rows)
    xfer = sum(r.seconds for r in ex.data.transfers)
    return {"wall_s": round(wall, 3), "makespan_s": round(span, 3),
            "transfer_s": round(xfer, 3), "dedup_hits": ex.data.dedup_hits}


def _median(runs) -> dict:
    runs = sorted(runs, key=lambda r: r["makespan_s"])
    return runs[len(runs) // 2]


def _compare(doc_fn, *, repeats: int = 3, **variants) -> dict:
    """Interleave the variants' runs (A,B,A,B,...) so CPU-state drift over
    the benchmark hits every mode equally; median-of-N per variant."""
    acc = {name: [] for name in variants}
    for _ in range(repeats):
        for name, kw in variants.items():
            acc[name].append(_one(doc_fn, **kw))
    return {name: _median(runs) for name, runs in acc.items()}


def run(verbose=True):
    warmup()
    rows = []
    for label, doc_fn in (("fig8", _fig8_doc), ("fig9", _fig9_doc)):
        got = _compare(doc_fn,
                       **{"serialized-fcfs": {"pipelined": False},
                          "pipelined": {"pipelined": True}})
        for mode, r in got.items():
            rows.append({"topology": label, "mode": mode, **r})
    queue = _compare(_fig9_doc, repeats=1,
                     **{f"pipelined+{p}": {"pipelined": True, "policy": p}
                        for p in QUEUE_POLICIES[1:]})
    for mode, r in queue.items():
        rows.append({"topology": "fig9", "mode": mode, **r})

    if verbose:
        hdr = ["topology", "mode", "wall_s", "makespan_s", "transfer_s",
               "dedup_hits"]
        print(" | ".join(f"{h:>18s}" for h in hdr))
        for r in rows:
            print(" | ".join(f"{str(r[h]):>18s}" for h in hdr))
        fig9 = {r["mode"]: r for r in rows if r["topology"] == "fig9"}
        s, p = fig9["serialized-fcfs"], fig9["pipelined"]
        print(f"\n[claim] hybrid (Fig.9) pipelined makespan "
              f"{p['makespan_s']:.3f}s vs serialized {s['makespan_s']:.3f}s "
              f"({s['makespan_s'] / max(p['makespan_s'], 1e-9):.2f}x): "
              f"transfers overlap compute instead of holding worker slots")
    return rows


def main(argv=None):
    run()


if __name__ == "__main__":
    main()
